"""CBSparseLinear: forward + custom VJP vs the dense equivalent."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import linear as L
from repro.sparse.prune import block_magnitude_prune, block_sparsity_pattern


@pytest.mark.parametrize("impl", ["reference", "pallas"])
@pytest.mark.parametrize("in_f,out_f,B,keep", [
    (96, 64, 16, 0.4),
    (64, 96, 16, 0.25),
    (64, 64, 8, 0.6),
])
def test_forward_matches_dense(impl, in_f, out_f, B, keep):
    params, spec = L.cb_linear_init(
        jax.random.PRNGKey(0), in_f, out_f, block_size=B, keep_fraction=keep
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 5, in_f))
    W = L.dense_equivalent(params, spec)
    got = L.cb_linear_apply(params, spec, x, impl=impl, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ W), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("impl", ["reference", "pallas"])
def test_vjp_matches_dense(impl):
    params, spec = L.cb_linear_init(
        jax.random.PRNGKey(0), 96, 64, block_size=16, keep_fraction=0.4
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 96))
    W = L.dense_equivalent(params, spec)

    gx = jax.grad(lambda xx: jnp.sum(jnp.sin(
        L.cb_linear_apply(params, spec, xx, impl=impl, interpret=True)
    )))(x)
    gx0 = jax.grad(lambda xx: jnp.sum(jnp.sin(xx @ W)))(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx0),
                               rtol=1e-4, atol=1e-4)

    g_t = jax.grad(lambda t: jnp.sum(jnp.sin(
        L.cb_linear_apply({"tiles": t}, spec, x, impl=impl, interpret=True)
    )))(params["tiles"])
    gW = jax.grad(lambda Wd: jnp.sum(jnp.sin(x @ Wd)))(W)
    B = spec.block_size
    gA = np.asarray(jnp.pad(gW.T, ((0, spec.mb * B - 64), (0, spec.nb * B - 96))))
    for t in range(spec.num_tiles):
        r0, c0 = spec.brow[t] * B, spec.bcol[t] * B
        np.testing.assert_allclose(
            np.asarray(g_t[t]), gA[r0 : r0 + B, c0 : c0 + B],
            rtol=1e-4, atol=1e-4,
        )


def test_grad_under_scan():
    """custom_vjp must survive lax.scan over stacked tiles (trace hygiene)."""
    params, spec = L.cb_linear_init(jax.random.PRNGKey(0), 32, 32,
                                    block_size=16, keep_fraction=0.6)
    tiles3 = jnp.stack([params["tiles"]] * 3)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))

    def loss(t3):
        def body(h, tiles):
            return jnp.tanh(L.cb_linear_apply({"tiles": tiles}, spec, h)), None
        h, _ = jax.lax.scan(body, x, t3)
        return jnp.sum(h)

    g = jax.grad(loss)(tiles3)
    assert g.shape == tiles3.shape
    assert np.isfinite(np.asarray(g)).all()


def test_block_pruning_properties():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 96)).astype(np.float32)
    mask = block_sparsity_pattern(w, 16, keep_fraction=0.25)
    keep = round(0.25 * 24)
    assert mask.shape == (4, 6)
    # exact keep count, plus up to one coverage block per empty row
    assert keep <= mask.sum() <= keep + 4
    assert mask.any(axis=1).all()          # row coverage
    # the top-`keep` blocks by Frobenius norm are all kept
    norms = np.transpose(w.reshape(4, 16, 6, 16), (0, 2, 1, 3))
    norms = (norms ** 2).sum(axis=(2, 3))
    top = np.argsort(norms.reshape(-1))[-keep:]
    assert mask.reshape(-1)[top].all()
    block_magnitude_prune(w, 16, 0.25)  # smoke: dense path runs


@pytest.mark.parametrize("G", [1, 4])
def test_forward_and_grads_with_group_size(G):
    """group_size rides through BOTH VJP streams (forward and transposed
    dX) as a schedule change only: pallas-batched gradients match the
    unbatched reference path's bit for bit on this integer-friendly size,
    and to float tolerance in general."""
    params, spec = L.cb_linear_init(
        jax.random.PRNGKey(0), 96, 64, block_size=16, keep_fraction=0.4
    )
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 96))

    def loss(impl, group_size):
        return lambda t: jnp.sum(jnp.sin(L.cb_linear_apply(
            {"tiles": t}, spec, x, impl=impl, interpret=True,
            group_size=group_size,
        )))

    y_ref = L.cb_linear_apply(params, spec, x, impl="reference")
    y_b = L.cb_linear_apply(params, spec, x, impl="pallas", interpret=True,
                            group_size=G)
    np.testing.assert_allclose(np.asarray(y_b), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)

    g_ref = jax.grad(loss("reference", None))(params["tiles"])
    # the reference path ignores grouping entirely — bit-identical
    g_ref_g = jax.grad(loss("reference", G))(params["tiles"])
    np.testing.assert_array_equal(np.asarray(g_ref), np.asarray(g_ref_g))
    g_b = jax.grad(loss("pallas", G))(params["tiles"])
    np.testing.assert_allclose(np.asarray(g_b), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-4)


def test_matmul_cache_drops_dead_specs():
    """The matmul cache must not pin every spec ever built (the old
    id()-keyed dict did, deliberately and unboundedly)."""
    import gc

    before = len(L._MATMUL_CACHE)
    specs = [
        L.cb_spec_random(64, 64, block_size=16, keep_fraction=0.5, seed=s)
        for s in range(12)
    ]
    for spec in specs:
        L._cached_matmul(spec, "reference", None)
        assert L._cached_matmul(spec, "reference", None) is (
            L._cached_matmul(spec, "reference", None)
        )  # hit path: same closure back
    assert len(L._MATMUL_CACHE) >= before + 12
    del specs, spec  # the loop variable pins the last spec otherwise
    gc.collect()
    assert len(L._MATMUL_CACHE) <= before


def test_spec_random_structural():
    spec = L.cb_spec_random(256, 128, block_size=32, keep_fraction=0.5, seed=1)
    assert spec.mb == 4 and spec.nb == 8
    assert spec.num_tiles == round(0.5 * 32)
    # transpose stream covers every block row of A^T
    assert set(np.asarray(spec.browT).tolist()) == set(range(spec.nb))
    # deterministic
    spec2 = L.cb_spec_random(256, 128, block_size=32, keep_fraction=0.5, seed=1)
    np.testing.assert_array_equal(spec.brow, spec2.brow)


# ---------------------------------------------------------------------------
# Mask refreeze: periodic re-pruning with spec-identity stability
# ---------------------------------------------------------------------------

def test_spec_from_mask_matches_init_structure():
    from repro.sparse import spec_block_mask, spec_from_mask

    params, spec = L.cb_linear_init(
        jax.random.PRNGKey(0), 48, 32, block_size=16, keep_fraction=0.6
    )
    spec2 = spec_from_mask(spec_block_mask(spec), 48, 32,
                           block_size=16, keep_fraction=0.6)
    for f in ("brow", "bcol", "t_perm", "browT", "bcolT"):
        np.testing.assert_array_equal(getattr(spec2, f), getattr(spec, f))
    assert (spec2.mb, spec2.nb) == (spec.mb, spec.nb)


def test_spec_from_mask_row_coverage_and_validation():
    from repro.sparse import spec_from_mask

    mask = np.zeros((2, 3), bool)
    mask[0, 2] = True  # block row 1 empty -> coverage pad at (1, 0)
    spec = spec_from_mask(mask, 48, 32, block_size=16, keep_fraction=0.1)
    assert (1, 0) in set(zip(spec.brow.tolist(), spec.bcol.tolist()))
    with pytest.raises(ValueError, match="block grid"):
        spec_from_mask(np.zeros((3, 3), bool), 48, 32,
                       block_size=16, keep_fraction=0.1)


def test_gather_tiles_roundtrips_dense_equivalent():
    from repro.sparse import dense_equivalent, gather_tiles

    params, spec = L.cb_linear_init(
        jax.random.PRNGKey(1), 64, 48, block_size=16, keep_fraction=0.5
    )
    a = np.asarray(dense_equivalent(params, spec)).T  # (out, in)
    np.testing.assert_array_equal(gather_tiles(a, spec),
                                  np.asarray(params["tiles"]))


def test_refreeze_mask_stable_returns_same_objects():
    from repro.sparse import refreeze_spec

    params, spec = L.cb_linear_init(
        jax.random.PRNGKey(2), 48, 32, block_size=16, keep_fraction=0.6
    )
    mm = L._cached_matmul(spec, "reference", None, None)
    p2, s2, changed = refreeze_spec(params, spec)
    assert not changed
    assert s2 is spec and p2 is params  # identity: plan + VJP cache survive
    assert L._cached_matmul(s2, "reference", None, None) is mm


def test_refreeze_drift_rebuilds_and_transfers_values():
    from repro.sparse import (
        dense_equivalent, refreeze_spec, spec_block_mask,
    )

    params, spec = L.cb_linear_init(
        jax.random.PRNGKey(3), 48, 32, block_size=16, keep_fraction=0.8
    )
    p2, s2, changed = refreeze_spec(params, spec, keep_fraction=0.3)
    assert changed and s2 is not spec
    assert s2.num_tiles < spec.num_tiles
    # surviving blocks keep their exact values
    a_old = np.asarray(dense_equivalent(params, spec)).T
    a_new = np.asarray(dense_equivalent(p2, s2)).T
    mask = spec_block_mask(s2)
    B = 16
    full = np.repeat(np.repeat(mask, B, 0), B, 1)[:32, :48]
    np.testing.assert_array_equal(a_new, a_old * full)


def test_refreeze_training_step_loop():
    """12 EF-int8 SGD steps with every_k=4: loss decreases and the spec
    object stays THE SAME whenever the mask does not drift."""
    from repro.sparse import refreeze_training_step
    from repro.training.grad_compression import init_ef_buffers

    params, spec = L.cb_linear_init(
        jax.random.PRNGKey(4), 48, 32, block_size=16, keep_fraction=0.6
    )
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((8, 48)), jnp.float32)
    y = x @ jnp.asarray(rng.standard_normal((48, 32)) * 0.1, jnp.float32)
    ef = init_ef_buffers(params)
    p, s = params, spec
    losses, spec_ids = [], []
    for step in range(12):
        p, ef, s, loss, changed = refreeze_training_step(
            p, ef, s, x, y, step=step, every_k=4, lr=0.05
        )
        losses.append(float(loss))
        spec_ids.append(id(s))
        if not changed:
            assert spec_ids[-1] == id(s)
    assert losses[-1] < losses[0]
    # stability: consecutive steps without a refreeze share the object
    assert spec_ids[0] == spec_ids[1] == spec_ids[2] == spec_ids[3]


def test_refreeze_due_schedule():
    from repro.sparse import refreeze_due

    assert not refreeze_due(0, 4)
    assert refreeze_due(4, 4) and refreeze_due(8, 4)
    assert not refreeze_due(5, 4)
    assert not refreeze_due(7, 0)  # disabled


def test_ef_compress_grads_error_feedback_contract():
    from repro.training.grad_compression import ef_compress_grads

    rng = np.random.default_rng(6)
    g = {"tiles": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32)}
    e = {"tiles": jnp.zeros((3, 4), jnp.float32)}
    dg, ne = ef_compress_grads(g, e)
    # dequantized + error == original (EF absorbs the rounding exactly)
    np.testing.assert_allclose(
        np.asarray(dg["tiles"]) + np.asarray(ne["tiles"]),
        np.asarray(g["tiles"]), atol=1e-6,
    )
