"""CB101 negative: the compat shim is the sanctioned spelling."""
from repro.compat import tpu_compiler_params


def build_params():
    return tpu_compiler_params(dimension_semantics=("parallel",))
