"""CB301 positive: the SpMM lane width re-hardcoded as 128."""


def spmm_launch(stream, x, block_n=128):
    return stream, x, block_n


def run(stream, x):
    return spmm_launch(stream, x, block_n=128)
