"""CB104 negative: compat.make_mesh handles the kwarg drift."""
from repro.compat import make_mesh


def build_mesh():
    return make_mesh((1,), ("x",))
