"""CB001 positive: suppressions that excuse nothing must themselves fire."""
TOTAL = 1 + 1  # cblint: disable=CB999
COUNT = 2 + 2  # cblint: disable=CB301
