"""CB401 negative: taxonomy raises carrying a stable reason code."""
from repro import errors


def check_group(group_size):
    if group_size < 1:
        raise errors.InvalidArgError(
            f"group_size must be >= 1, got {group_size}"
        )
    raise NotImplementedError("builtin escapes outside the rule are fine")
