"""CB501 negative: repro.<subsystem>.<metric> names everywhere."""
from repro import obs


def record(kind):
    obs.counter("repro.fixture.calls").inc()
    obs.gauge("repro.fixture.depth").set(1)
    obs.histogram(f"repro.fixture.{kind}_latency").observe(0.1)
    mirrored = obs.MirroredCounter(
        metric="repro.fixture.lookups", label="outcome")
    return mirrored
