"""CB103 positive: both drifting shard_map spellings."""
import jax
from jax.experimental.shard_map import shard_map


def wrap(f, mesh, specs):
    legacy = shard_map(f, mesh=mesh, in_specs=specs, out_specs=specs)
    modern = jax.shard_map(f, mesh=mesh, in_specs=specs, out_specs=specs)
    return legacy, modern
