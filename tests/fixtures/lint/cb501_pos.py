"""CB501 positive: instrument names off the naming convention."""
from repro import obs


def record(kind):
    obs.counter("fixture_calls").inc()
    obs.gauge("repro.depth").set(1)
    obs.histogram(f"{kind}.latency").observe(0.1)
    mirrored = obs.MirroredCounter(metric="lookups", label="outcome")
    return mirrored
