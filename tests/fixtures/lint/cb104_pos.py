"""CB104 positive: axis_types= does not exist on JAX 0.4.x."""
import jax


def build_mesh(axis_type):
    return jax.make_mesh((1,), ("x",), axis_types=(axis_type,))
