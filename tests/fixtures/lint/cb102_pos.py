"""CB102 positive: a raw pl.pallas_call call site outside compat.py."""
from jax.experimental import pallas as pl


def launch(kernel, out_shape):
    return pl.pallas_call(kernel, out_shape=out_shape)
