"""CB101 positive: drifting compiler-params spellings outside compat.py."""
from jax.experimental.pallas import tpu as pltpu


def build_params():
    return pltpu.CompilerParams(dimension_semantics=("parallel",))
