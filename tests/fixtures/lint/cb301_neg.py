"""CB301 negative: the lane width spelled via the single home."""
from repro.core.streams import LANE, spmm_block_n


def spmm_launch(stream, x, block_n=LANE):
    return stream, x, spmm_block_n(x.shape[1], block_n)
