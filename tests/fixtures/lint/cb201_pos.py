"""CB201 positive: host side effects inside traced code."""
import functools
import time

import jax
import numpy as np

from repro import obs


@functools.partial(jax.jit, static_argnames=("mode",))
def _apply_jit(x, *, mode="fast"):
    obs.counter("repro.fixture.calls").inc()
    print("tracing", mode)
    noise = np.random.default_rng(0).normal()
    t0 = time.perf_counter()
    return x * noise + t0


def _scale_kernel(x_ref, o_ref):
    print("inside kernel")
    o_ref[...] = x_ref[...] * 2.0
