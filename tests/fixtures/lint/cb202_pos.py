"""CB202 positive: materializing a tracer inside jitted code."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("mode",))
def _collapse_jit(x, threshold, *, mode="fast"):
    scalar = float(threshold)
    total = x.sum().item()
    return x * scalar + total
