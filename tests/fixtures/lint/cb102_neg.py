"""CB102 negative: kernels launch through compat.pallas_call_tpu."""
from repro.compat import pallas_call_tpu


def launch(kernel, out_shape):
    return pallas_call_tpu(kernel, out_shape=out_shape, interpret=True)
