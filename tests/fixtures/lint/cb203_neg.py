"""CB203 negative: hashable statics (tuples, frozen values, None)."""
import functools

import jax


def _solve(x, opts):
    return x


_solve_jit = jax.jit(_solve, static_argnums=(1,))
result = _solve_jit(1.0, (4, 5))


@functools.partial(jax.jit, static_argnames=("opts",))
def _plan_jit(x, *, opts=None):
    return x


def run(stream, x):
    return _plan_jit(x, opts=("depth", 3))
