"""CB001 negative: a pragma on a line where the named rule really fires."""


def reject(value):
    raise ValueError(value)  # cblint: disable=CB401
