"""CB201 negative: the same side effects in host-side shims are fine."""
import time

import numpy as np

from repro import obs


def apply_shim(x):
    obs.counter("repro.fixture.calls").inc()
    noise = np.random.default_rng(0).normal()
    t0 = time.perf_counter()
    print("host side", t0)
    return x * noise
