"""CB302 positive: alignment arithmetic with magic literals in kernels/."""


def pack_rows(width, lane):
    slots = lane // 8
    if width % 128:
        width = width + (128 - width % 128)
    return slots, width
