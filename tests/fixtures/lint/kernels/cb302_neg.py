"""CB302 negative: alignment arithmetic through the named constants."""
from repro.core.streams import LANE, SUBLANE


def pack_rows(width, lane):
    slots = lane // SUBLANE
    if width % LANE:
        width = width + (LANE - width % LANE)
    return slots, width
