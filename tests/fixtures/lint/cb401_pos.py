"""CB401 positive: untyped builtin raises in library code."""


def check_group(group_size):
    if group_size < 1:
        raise ValueError(f"group_size must be >= 1, got {group_size}")
    if group_size > 64:
        raise RuntimeError("group too large")
