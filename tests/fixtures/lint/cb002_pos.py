"""CB002 positive: the analyzer reports parse errors as findings."""
def broken(:
