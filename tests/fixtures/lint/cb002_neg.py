"""CB002 negative: a well-formed file produces no parse finding."""
VALUE = 42
