"""CB103 negative: the version-stable compat entry point."""
from repro.compat import shard_map


def wrap(f, mesh, specs):
    return shard_map(f, mesh=mesh, in_specs=specs, out_specs=specs)
