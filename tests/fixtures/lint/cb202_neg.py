"""CB202 negative: host-side materialization and static-arg coercion."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("mode",))
def _scale_jit(x, *, mode=2):
    return x * int(mode)


def collapse(x, threshold):
    return float(threshold) + x.sum().item()
